"""Paper Fig. 7 (theory): parallel-space and mapping-work improvement of
lambda(w) over bounding-box, exact closed forms (Lemmas 1-2, Theorem 2).
"""
from __future__ import annotations

import math

from repro.core import fractal as F
from repro.core.domain import (BandDomain, SierpinskiDomain,
                               TriangularDomain)
from .common import row


def run(max_r: int = 16):
    print("# Theorem 2: work ratio BB/lambda; space ratio n^2/n^H")
    for r in range(1, max_r + 1):
        n = 2 ** r
        v = F.gasket_volume(n)
        ox, oy = F.orthotope_shape(r)
        space_ratio = (n * n) / v
        # work model: BB does O(1) per block over n^2 blocks; lambda does
        # O(log2 log2 n) per block over n^H blocks (paper Eq. 11)
        work_lam = v * max(1.0, math.log2(max(2.0, math.log2(n))))
        work_ratio = (n * n) / work_lam
        row(f"space_eff/r={r}", 0.0,
            f"n={n};V={v};orthotope={ox}x{oy};space_ratio="
            f"{space_ratio:.3f};work_ratio={work_ratio:.3f}")
    print("# block-space domains generalization (DESIGN.md SS3)")
    for m in (64, 256, 1024):
        row(f"domain_eff/triangular/m={m}", 0.0,
            f"blocks={TriangularDomain(m).num_blocks};bb={m * m};"
            f"eff={TriangularDomain(m).space_efficiency():.4f}")
        bd = BandDomain(m, 8)
        row(f"domain_eff/band8/m={m}", 0.0,
            f"blocks={bd.num_blocks};bb={m * m};"
            f"eff={bd.space_efficiency():.4f}")
        sd = SierpinskiDomain(m)
        row(f"domain_eff/sierpinski/m={m}", 0.0,
            f"blocks={sd.num_blocks};bb={m * m};"
            f"eff={sd.space_efficiency():.4f}")


if __name__ == "__main__":
    run()
