"""Shared timing utilities for the benchmark harness."""
from __future__ import annotations

import json
import time

import jax
import numpy as np

#: every row() call is also collected here so harness entry points can
#: dump a machine-readable artifact next to the CSV stdout (CI uploads
#: benchmarks/*.json)
RESULTS: list = []


def time_fn(fn, *args, warmup: int = 3, iters: int = 20,
            sync=True) -> float:
    """Median wall-clock microseconds per call of a jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
    if sync:
        jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if sync:
            jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(samples))


def row(name: str, us: float, derived: str = ""):
    RESULTS.append({"name": name, "us_per_call": round(us, 2),
                    "derived": derived})
    print(f"{name},{us:.2f},{derived}")


def git_revision() -> dict:
    """``{"commit": <sha>, "dirty": <bool>}`` for the repo this file
    lives in, or ``{}`` when git (or the repo) is unavailable -- a
    benchmark artifact is attributable to a source state, not just a
    machine."""
    import os
    import subprocess
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip() != ""
    except (OSError, subprocess.SubprocessError):
        return {}
    return {"commit": sha, "dirty": dirty}


def run_metadata() -> dict:
    """Environment stamp for a benchmark artifact, so the perf
    trajectory stays attributable across machines and source states:
    jax/jaxlib versions, backend, device count and kinds, host platform
    and Python, plus the git commit (and dirty flag) the run came
    from."""
    import platform

    import jaxlib

    from repro.core import backend as backend_lib
    devs = jax.devices()
    return {
        **git_revision(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        # the kernel-emission target (repro.core.backend) the run's
        # Pallas calls defaulted to -- gpu-interpret CI rows stay
        # distinguishable from tpu-interpret ones
        "kernel_target": backend_lib.resolve(None).name,
        "device_count": len(devs),
        "device_kinds": sorted({d.device_kind for d in devs}),
        "process_count": jax.process_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def dump_json(path: str):
    """Write every row() recorded so far to ``path``:
    ``{"meta": run_metadata(), "rows": [...]}``."""
    with open(path, "w") as f:
        json.dump({"meta": run_metadata(), "rows": RESULTS}, f, indent=1)
    print(f"# wrote {len(RESULTS)} rows to {path}")
