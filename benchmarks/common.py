"""Shared timing utilities for the benchmark harness."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 3, iters: int = 20,
            sync=True) -> float:
    """Median wall-clock microseconds per call of a jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
    if sync:
        jax.block_until_ready(out)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if sync:
            jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(samples))


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}")
