"""SS-Perf hillclimb driver: run the three selected cells through their
optimization variants (each a dryrun --opt override set), collect the
roofline terms, and print the iteration log table.

Variants are cumulative where that matches the methodology (biggest
predicted win first); every run lands in results/hillclimb/ so the
before/after chain is auditable.
"""
from __future__ import annotations

import json
import os
import sys

from repro.launch.dryrun import run_cell_subprocess

# (cell, [(variant-name, opt-string or None for baseline)])
PLAN = [
    ("gemma3-12b", "prefill_32k", [
        ("baseline", None),
        ("tri", "attn_schedule=triangular"),
        ("tri+msp", "attn_schedule=triangular,megatron_sp=true"),
        ("tri+msp+chunk2k",
         "attn_schedule=triangular,megatron_sp=true,attn_chunk=2048"),
    ]),
    ("qwen2.5-32b", "train_4k", [
        ("baseline", None),
        ("msp", "megatron_sp=true"),
        ("msp+tri", "megatron_sp=true,attn_schedule=triangular"),
        ("msp+tri+accum2",
         "megatron_sp=true,attn_schedule=triangular,grad_accum=2"),
    ]),
    ("deepseek-v2-236b", "train_4k", [
        ("baseline", None),
        ("epdata", "ep_data=true"),
        ("epdata+msp+tri",
         "ep_data=true,megatron_sp=true,attn_schedule=triangular"),
        ("epdata+msp+tri+accum8",
         "ep_data=true,megatron_sp=true,attn_schedule=triangular,"
         "grad_accum=8"),
    ]),
]


def run(results_dir="results/hillclimb", mesh="single"):
    os.makedirs(results_dir, exist_ok=True)
    rows = []
    for arch, shape, variants in PLAN:
        for name, opt in variants:
            out = os.path.join(results_dir,
                               f"{arch}__{shape}__{name}.json")
            if name == "baseline" and not os.path.exists(out):
                base = os.path.join("results/dryrun",
                                    f"{arch}__{shape}__{mesh}.json")
                if os.path.exists(base):
                    import shutil
                    shutil.copy(base, out)
            if not os.path.exists(out):
                print(f"running {arch} {shape} [{name}] ...", flush=True)
                r = run_cell_subprocess(arch, shape, mesh, out, opt=opt)
                if r.returncode != 0 or not os.path.exists(out):
                    print(f"  FAILED:\n{r.stdout[-1500:]}\n"
                          f"{r.stderr[-3000:]}")
                    continue
            rec = json.load(open(out))
            rec = rec if isinstance(rec, dict) else rec[0]
            ro = rec["roofline"]
            rows.append((arch, shape, name, rec["mem"]["peak_est_gib"],
                         ro["compute_s"], ro["memory_s"],
                         ro["collective_s"], ro["dominant"],
                         ro["useful_ratio"], ro["roofline_frac"]))
    print("\narch,shape,variant,mem_gib,compute_s,memory_s,collective_s,"
          "bound,useful_ratio,roofline_frac")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.1f},{r[4]:.3f},{r[5]:.3f},"
              f"{r[6]:.3f},{r[7]},{r[8]:.3f},{r[9]:.4f}")
    return rows


if __name__ == "__main__":
    run(*(sys.argv[1:]))
