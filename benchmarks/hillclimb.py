"""SS-Perf hillclimb driver.

Two suites:

* LM dryrun cells (the original): run the three selected cells through
  their optimization variants (each a dryrun --opt override set),
  collect the roofline terms, and print the iteration log table.
* Fractal-kernel cells (``python -m benchmarks.hillclimb fractal``):
  the CA / write kernels swept over the scheduling axes
  ``lowering x storage x fuse x coarsen``, riding the autotuner's
  measurement path (:func:`repro.core.tune.autotune`) so the hillclimb
  table and the tuner can never disagree about what was measured.

Variants are cumulative where that matches the methodology (biggest
predicted win first); every run lands in results/hillclimb/ so the
before/after chain is auditable.
"""
from __future__ import annotations

import json
import os
import sys

from repro.launch.dryrun import run_cell_subprocess

# (cell, [(variant-name, opt-string or None for baseline)])
PLAN = [
    ("gemma3-12b", "prefill_32k", [
        ("baseline", None),
        ("tri", "attn_schedule=triangular"),
        ("tri+msp", "attn_schedule=triangular,megatron_sp=true"),
        ("tri+msp+chunk2k",
         "attn_schedule=triangular,megatron_sp=true,attn_chunk=2048"),
    ]),
    ("qwen2.5-32b", "train_4k", [
        ("baseline", None),
        ("msp", "megatron_sp=true"),
        ("msp+tri", "megatron_sp=true,attn_schedule=triangular"),
        ("msp+tri+accum2",
         "megatron_sp=true,attn_schedule=triangular,grad_accum=2"),
    ]),
    ("deepseek-v2-236b", "train_4k", [
        ("baseline", None),
        ("epdata", "ep_data=true"),
        ("epdata+msp+tri",
         "ep_data=true,megatron_sp=true,attn_schedule=triangular"),
        ("epdata+msp+tri+accum8",
         "ep_data=true,megatron_sp=true,attn_schedule=triangular,"
         "grad_accum=8"),
    ]),
]


def run(results_dir="results/hillclimb", mesh="single"):
    os.makedirs(results_dir, exist_ok=True)
    rows = []
    for arch, shape, variants in PLAN:
        for name, opt in variants:
            out = os.path.join(results_dir,
                               f"{arch}__{shape}__{name}.json")
            if name == "baseline" and not os.path.exists(out):
                base = os.path.join("results/dryrun",
                                    f"{arch}__{shape}__{mesh}.json")
                if os.path.exists(base):
                    import shutil
                    shutil.copy(base, out)
            if not os.path.exists(out):
                print(f"running {arch} {shape} [{name}] ...", flush=True)
                r = run_cell_subprocess(arch, shape, mesh, out, opt=opt)
                if r.returncode != 0 or not os.path.exists(out):
                    print(f"  FAILED:\n{r.stdout[-1500:]}\n"
                          f"{r.stderr[-3000:]}")
                    continue
            rec = json.load(open(out))
            rec = rec if isinstance(rec, dict) else rec[0]
            ro = rec["roofline"]
            rows.append((arch, shape, name, rec["mem"]["peak_est_gib"],
                         ro["compute_s"], ro["memory_s"],
                         ro["collective_s"], ro["dominant"],
                         ro["useful_ratio"], ro["roofline_frac"]))
    print("\narch,shape,variant,mem_gib,compute_s,memory_s,collective_s,"
          "bound,useful_ratio,roofline_frac")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.1f},{r[4]:.3f},{r[5]:.3f},"
              f"{r[6]:.3f},{r[7]},{r[8]:.3f},{r[9]:.4f}")
    return rows


# (cell-name, kernel, autotune kwargs): the fractal-kernel hillclimb
# cells; the variant axes are the autotuner's full candidate product
# lowering x storage x fuse x coarsen (write has no fuse axis).
FRACTAL_CELLS = [
    ("ca-gasket-n128-parity", "ca",
     dict(n=128, block=8, rule="parity", max_fuse=8, max_coarsen=4)),
    ("ca-gasket-n64-diffusion", "ca",
     dict(n=64, block=8, rule="diffusion", max_fuse=8, max_coarsen=2)),
    ("write-gasket-n256", "write",
     dict(n=256, block=16, max_coarsen=4)),
]


def _variant_name(cfg):
    bits = [cfg["lowering"], cfg["storage"]]
    if cfg.get("fuse", 1) != 1:
        bits.append(f"fuse{cfg['fuse']}")
    if cfg.get("coarsen", 1) != 1:
        bits.append(f"coarsen{cfg['coarsen']}")
    return "+".join(bits)


def run_fractal(results_dir="results/hillclimb"):
    """Measure every scheduling variant of the fractal cells and print
    the iteration log, baseline (bounding / embedded / unfused) first."""
    from repro.core import tune

    os.makedirs(results_dir, exist_ok=True)
    rows = []
    for name, kernel, kw in FRACTAL_CELLS:
        cache = tune.TuneCache(os.path.join(results_dir,
                                            f"fractal__{name}.json"))
        search = tune.autotune_ca if kernel == "ca" else \
            tune.autotune_write
        print(f"measuring {name} "
              f"(lowering x storage x fuse x coarsen) ...", flush=True)
        best_cfg, best_us, trials = search(cache=cache, force=True, **kw)
        with open(os.path.join(results_dir,
                               f"fractal__{name}__trials.json"),
                  "w") as f:
            json.dump([{**c, "us": round(u, 2)} for c, u in trials], f,
                      indent=1)
        base = next((u for c, u in trials
                     if c["lowering"] == "bounding"
                     and c["storage"] == "embedded"
                     and c.get("fuse", 1) == 1
                     and c.get("coarsen", 1) == 1), None)
        for cfg, us in sorted(trials, key=lambda t: -t[1]):
            rows.append((name, _variant_name(cfg), us,
                         base / us if base else float("nan"),
                         cfg == best_cfg))
    print("\ncell,variant,us_per_call,speedup_vs_baseline,winner")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]:.1f},{r[3]:.2f},"
              f"{'*' if r[4] else ''}")
    return rows


if __name__ == "__main__":
    if sys.argv[1:2] == ["fractal"]:
        run_fractal(*(sys.argv[2:]))
    else:
        run(*(sys.argv[1:]))
