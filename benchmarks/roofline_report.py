"""Render EXPERIMENTS.md SS-Roofline tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        r = json.load(open(f))
        recs.extend(r if isinstance(r, list) else [r])
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(recs, mesh="16x16"):
    rows = []
    hdr = ("| arch | shape | mem/dev | compute | memory | collective | "
           "bound | useful ratio | roofline frac |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    recs = [r for r in recs if r["mesh"] == mesh]
    recs.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])))
    for r in recs:
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['mem']['peak_est_gib']:.1f}G | "
            f"{fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} | "
            f"{fmt_s(ro['collective_s'])} | {ro['dominant'][:4]} | "
            f"{ro['useful_ratio']:.3f} | {ro['roofline_frac']:.3f} |")
    return "\n".join(rows)


def summary(recs):
    out = []
    by_dom = defaultdict(int)
    for r in recs:
        if r["mesh"] == "16x16":
            by_dom[r["roofline"]["dominant"]] += 1
    out.append(f"bound distribution (single pod): {dict(by_dom)}")
    worst = sorted((r for r in recs if r["mesh"] == "16x16"),
                   key=lambda r: r["roofline"]["roofline_frac"])[:5]
    out.append("worst roofline fractions: " + ", ".join(
        f"{r['arch']}/{r['shape']}={r['roofline']['roofline_frac']:.3f}"
        for r in worst))
    coll = sorted((r for r in recs if r["mesh"] == "16x16"),
                  key=lambda r: -r["roofline"]["collective_s"])[:5]
    out.append("most collective-bound: " + ", ".join(
        f"{r['arch']}/{r['shape']}={fmt_s(r['roofline']['collective_s'])}"
        for r in coll))
    return "\n".join(out)


def main():
    recs = load()
    print(f"cells loaded: {len(recs)}")
    print("\n## single-pod (16x16 = 256 chips)\n")
    print(table(recs, "16x16"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(table(recs, "2x16x16"))
    print("\n## summary\n")
    print(summary(recs))


if __name__ == "__main__":
    main()
