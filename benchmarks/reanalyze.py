"""Re-run the HLO cost walker over saved .hlo.gz artifacts and refresh
the roofline block of each results JSON -- lets walker improvements
propagate without recompiling the 66 cells."""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.launch import hlo_analysis
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS


def reanalyze(json_path: str) -> bool:
    hlo_path = json_path.replace(".json", ".hlo.gz")
    if not os.path.exists(hlo_path):
        return False
    rec = json.load(open(json_path))
    single = isinstance(rec, dict)
    recs = [rec] if single else rec
    with gzip.open(hlo_path, "rt") as f:
        txt = f.read()
    cost = hlo_analysis.analyze(txt)
    for r in recs:
        useful = r["roofline"]["model_flops_total"]
        chips = r["chips"]
        compute_s = cost.flops / PEAK_FLOPS
        memory_s = cost.bytes_accessed / HBM_BW
        coll_s = cost.coll_wire_bytes / ICI_BW
        dominant = max((("compute", compute_s), ("memory", memory_s),
                        ("collective", coll_s)), key=lambda kv: kv[1])[0]
        r["hlo"].update({
            "flops_per_dev": cost.flops,
            "bytes_per_dev": cost.bytes_accessed,
            "coll_bytes_per_dev": cost.coll_bytes,
            "coll_wire_bytes_per_dev": cost.coll_wire_bytes,
            "coll_by_type": dict(cost.coll_by_type),
            "coll_count": dict(cost.coll_count),
            "bytes_by_op": dict(sorted(cost.bytes_by_op.items(),
                                       key=lambda kv: -kv[1])[:12]),
        })
        r["roofline"].update({
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "useful_ratio": useful / chips / max(cost.flops, 1.0),
            "roofline_s": max(compute_s, memory_s, coll_s),
            "roofline_frac": min(1.0, useful / chips / PEAK_FLOPS
                                 / max(compute_s, memory_s, coll_s)),
        })
    json.dump(rec, open(json_path, "w"), indent=2)
    return True


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    n = 0
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        if reanalyze(p):
            n += 1
    print(f"reanalyzed {n} cells in {d}")


if __name__ == "__main__":
    main()
