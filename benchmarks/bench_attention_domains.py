"""Block-space attention: the paper's compact-vs-bounding-box comparison
applied to causal attention (DESIGN.md SS3).

Measures (a) compiled HLO FLOPs of the dense (bounding-box) vs
triangular (compact) schedules -- the Theorem-2 work ratio in the LM
setting -- and (b) CPU wall clock at a small config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import LOWERINGS
from repro.kernels import ops
from repro.launch.hlo_analysis import analyze
from repro.models.attention import flash_attention_xla
from .common import row, time_fn


def hlo_flops(schedule, b, h, s, d, chunk):
    def f(q, k, v):
        return flash_attention_xla(q, k, v, kind="causal", chunk=chunk,
                                   schedule=schedule)
    spec = jax.ShapeDtypeStruct((b, h, s, d), jnp.float32)
    compiled = jax.jit(f).lower(spec, spec, spec).compile()
    return analyze(compiled.as_text()).flops


def run_kernel_lowerings(iters: int = 5):
    """GridPlan lowering A/B on the Pallas flash kernel, per attention
    block domain (triangular / band / bounding-box) and block size."""
    print("# Pallas flash kernel: GridPlan lowering A/B per domain")
    rng = np.random.default_rng(0)
    for kind, kw, s, bq in (("causal", {}, 256, 64),
                            ("causal", {}, 256, 128),
                            ("local", {"window": 128}, 256, 64),
                            ("full", {}, 256, 64)):
        q = jnp.asarray(rng.normal(size=(1, 2, s, 32)), jnp.float32)
        t_closed = None
        for low in LOWERINGS:
            fn = functools.partial(ops.flash_attention, kind=kind,
                                   block_q=bq, block_k=bq,
                                   grid_mode=low, **kw)
            t = time_fn(fn, q, q, q, warmup=2, iters=iters)
            if t_closed is None:
                t_closed = t
            row(f"gridplan_flash/{kind}/s={s}/bq={bq}/{low}", t,
                f"speedup_vs_closed_form={t_closed / t:.2f}")


def run_backend_ab(iters: int = 5):
    """Flash-kernel backend A/B: compact vs bounding lowering per
    emission target (the gpu rows time the row-loop Triton structure;
    under the interpreter they validate it, on CUDA they measure
    it)."""
    from repro.core import backend as backend_lib
    default = backend_lib.resolve(None)
    other = (backend_lib.GPU if default.kind == "tpu"
             else backend_lib.TPU).emulated()
    print("# Pallas flash kernel: backend x lowering A/B (causal)")
    rng = np.random.default_rng(0)
    s, bq = 256, 64
    q = jnp.asarray(rng.normal(size=(1, 2, s, 32)), jnp.float32)
    for tname in (default.name, other.name):
        times = {}
        for low in ("closed_form", "bounding"):
            fn = functools.partial(ops.flash_attention, kind="causal",
                                   block_q=bq, block_k=bq,
                                   grid_mode=low, backend=tname)
            times[low] = time_fn(fn, q, q, q, warmup=2, iters=iters)
        row(f"backend_flash/{tname}/s={s}/bq={bq}/closed_form",
            times["closed_form"],
            f"speedup_vs_bounding="
            f"{times['bounding'] / times['closed_form']:.2f}")
        row(f"backend_flash/{tname}/s={s}/bq={bq}/bounding",
            times["bounding"], "")


def run():
    run_kernel_lowerings()
    run_backend_ab()
    print("# causal flash attention: dense (BB) vs triangular (compact)")
    b, h, d = 1, 4, 64
    for s, chunk in ((2048, 256), (4096, 512), (8192, 1024)):
        fd = hlo_flops("dense", b, h, s, d, chunk)
        ft = hlo_flops("triangular", b, h, s, d, chunk)
        row(f"attn_flops_dense/s={s}", 0.0, f"hlo_flops={fd:.3e}")
        row(f"attn_flops_tri/s={s}", 0.0,
            f"hlo_flops={ft:.3e};work_ratio={fd / ft:.3f}")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 4, 2048, 64)), jnp.float32)
    fn_d = jax.jit(functools.partial(flash_attention_xla, kind="causal",
                                     chunk=256, schedule="dense"))
    fn_t = jax.jit(functools.partial(flash_attention_xla, kind="causal",
                                     chunk=256, schedule="triangular"))
    td = time_fn(fn_d, q, q, q, iters=10)
    tt = time_fn(fn_t, q, q, q, iters=10)
    row("attn_wall_dense/s=2048", td, "")
    row("attn_wall_tri/s=2048", tt, f"speedup={td / tt:.2f}")


if __name__ == "__main__":
    run()
