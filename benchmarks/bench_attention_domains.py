"""Block-space attention: the paper's compact-vs-bounding-box comparison
applied to causal attention (DESIGN.md SS3).

Measures (a) compiled HLO FLOPs of the dense (bounding-box) vs
triangular (compact) schedules -- the Theorem-2 work ratio in the LM
setting -- and (b) CPU wall clock at a small config.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze
from repro.models.attention import flash_attention_xla
from .common import row, time_fn


def hlo_flops(schedule, b, h, s, d, chunk):
    def f(q, k, v):
        return flash_attention_xla(q, k, v, kind="causal", chunk=chunk,
                                   schedule=schedule)
    spec = jax.ShapeDtypeStruct((b, h, s, d), jnp.float32)
    compiled = jax.jit(f).lower(spec, spec, spec).compile()
    return analyze(compiled.as_text()).flops


def run():
    print("# causal flash attention: dense (BB) vs triangular (compact)")
    b, h, d = 1, 4, 64
    for s, chunk in ((2048, 256), (4096, 512), (8192, 1024)):
        fd = hlo_flops("dense", b, h, s, d, chunk)
        ft = hlo_flops("triangular", b, h, s, d, chunk)
        row(f"attn_flops_dense/s={s}", 0.0, f"hlo_flops={fd:.3e}")
        row(f"attn_flops_tri/s={s}", 0.0,
            f"hlo_flops={ft:.3e};work_ratio={fd / ft:.3f}")

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 4, 2048, 64)), jnp.float32)
    fn_d = jax.jit(functools.partial(flash_attention_xla, kind="causal",
                                     chunk=256, schedule="dense"))
    fn_t = jax.jit(functools.partial(flash_attention_xla, kind="causal",
                                     chunk=256, schedule="triangular"))
    td = time_fn(fn_d, q, q, q, iters=10)
    tt = time_fn(fn_t, q, q, q, iters=10)
    row("attn_wall_dense/s=2048", td, "")
    row("attn_wall_tri/s=2048", tt, f"speedup={td / tt:.2f}")


if __name__ == "__main__":
    run()
