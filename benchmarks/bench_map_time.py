"""Mapping-cost scaling: time the lambda(w) map itself (all blocks of a
level-r gasket) and the triangular/band decodes, jitted on CPU.

The paper's Theorem 1 cost is O(log log n) per block WITH a |B|-thread
reduction; on TPU the map runs as scalar index_map code of O(log n)
unrolled adds hidden behind the DMA pipeline (DESIGN.md SS2 deviation 1).
What we measure here is the full-grid map throughput, which is what the
XLA analogue actually pays.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fractal as F
from repro.core.domain import TriangularDomain
from .common import row, time_fn


@functools.partial(jax.jit, static_argnames=("r",))
def map_all(r):
    i = jnp.arange(3 ** r, dtype=jnp.int32)
    lx, ly = F.lambda_map_linear(i, r)
    return lx + ly


def run():
    print("# lambda map throughput (all 3^r blocks, jitted)")
    for r in range(4, 14):
        us = time_fn(map_all, r, iters=10)
        nb = 3 ** r
        row(f"lambda_map/r={r}", us, f"blocks={nb};ns_per_block="
            f"{1e3 * us / nb:.3f}")
    print("# triangular decode throughput")
    for m in (64, 256, 1024):
        t = TriangularDomain(m)

        @jax.jit
        def dec(i):
            k, q = t.block_coords(i)
            return k + q

        i = jnp.arange(t.num_blocks, dtype=jnp.int32)
        us = time_fn(dec, i, iters=10)
        row(f"tri_decode/m={m}", us,
            f"blocks={t.num_blocks};ns_per_block="
            f"{1e3 * us / t.num_blocks:.3f}")


if __name__ == "__main__":
    run()
