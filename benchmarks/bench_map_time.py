"""Mapping-cost scaling: time the lambda(w) map itself (all blocks of a
level-r gasket) under every registered GridPlan lowering, plus the
triangular/band decodes, jitted on CPU.

The paper's Theorem 1 cost is O(log log n) per block WITH a |B|-thread
reduction; on TPU the map runs as scalar index_map code of O(log n)
unrolled adds hidden behind the DMA pipeline (DESIGN.md SS2 deviation 1).
What we measure here is the full-grid map throughput, which is what the
XLA analogue actually pays -- per lowering, so the decode strategies
(inline integer unroll, LUT gather, dense-grid discard, digit-basis
matmul) land on the same axis.

The sweep is driven from :data:`repro.core.plan.LOWERINGS`: registering
a fifth lowering without teaching this benchmark its decode fails
loudly instead of silently dropping the row family.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fractal as F
from repro.core import mma
from repro.core.domain import TriangularDomain
from repro.core.plan import LOWERINGS
from .common import row, time_fn


@functools.partial(jax.jit, static_argnames=("r",))
def map_closed_form(i, r):
    lx, ly = F.lambda_map_linear(i, r)
    return lx + ly


@jax.jit
def map_prefetch_lut(i, lut):
    return lut[i, 0] + lut[i, 1]


@functools.partial(jax.jit, static_argnames=("r",))
def map_bounding(r):
    # the run-time-discard baseline decodes its full 2^r x 2^r grid
    n = 2 ** r
    i = jnp.arange(n * n, dtype=jnp.int32)
    return i % n + i // n


@functools.partial(jax.jit, static_argnames=("r",))
def map_mma(i, r):
    bx, by = mma.decode_linear(F.SIERPINSKI, r, i)
    return bx + by


def run_lowering_sweep(iters: int = 10):
    print("# lambda map throughput per registered lowering (all 3^r")
    print("#   member blocks, jitted; bounding decodes its 4^r dense")
    print("#   grid -- the run-time-discard cost the compact map avoids)")
    for r in range(4, 12):
        nb = 3 ** r
        i = jnp.arange(nb, dtype=jnp.int32)
        lut = jnp.stack(F.lambda_map_linear(i, r), axis=1)
        timers = {
            "closed_form": lambda: time_fn(map_closed_form, i, r,
                                           iters=iters),
            "prefetch_lut": lambda: time_fn(map_prefetch_lut, i, lut,
                                            iters=iters),
            "bounding": lambda: time_fn(map_bounding, r, iters=iters),
            "mma": lambda: time_fn(map_mma, i, r, iters=iters),
        }
        missing = set(LOWERINGS) - set(timers)
        if missing:
            raise RuntimeError(
                f"bench_map_time has no decode timer for registered "
                f"lowering(s) {sorted(missing)}")
        blocks = {low: (4 ** r if low == "bounding" else nb)
                  for low in LOWERINGS}
        t0 = None
        for low in LOWERINGS:
            us = timers[low]()
            if t0 is None:
                t0 = us
            row(f"lambda_map/{low}/r={r}", us,
                f"blocks={blocks[low]};ns_per_block="
                f"{1e3 * us / blocks[low]:.3f};"
                f"speedup_vs_closed_form={t0 / us:.2f}")


def run():
    run_lowering_sweep()
    print("# triangular decode throughput")
    for m in (64, 256, 1024):
        t = TriangularDomain(m)

        @jax.jit
        def dec(i):
            k, q = t.block_coords(i)
            return k + q

        i = jnp.arange(t.num_blocks, dtype=jnp.int32)
        us = time_fn(dec, i, iters=10)
        row(f"tri_decode/m={m}", us,
            f"blocks={t.num_blocks};ns_per_block="
            f"{1e3 * us / t.num_blocks:.3f}")


if __name__ == "__main__":
    run()
