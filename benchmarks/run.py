# One function per paper table. Print ``name,us_per_call,derived`` CSV
# and mirror the rows to a machine-readable artifact: by default
# ``BENCH_<tag>.json`` at the repo root (tag = jax backend), so every
# benchmark run leaves a comparable point on the perf trajectory.
# ``--json PATH`` overrides the path, ``--no-json`` suppresses it.
import argparse
import os

#: every suite ``--only`` accepts.  ``backend`` is opt-in only (the
#: per-target lambda-vs-bounding A/B rows are also part of map/attn),
#: hence its absence from the default no-``--only`` sweep below.
SUITES = ("map", "space", "time", "ca", "sched", "shard", "overlap",
          "attn", "backend", "serve")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: " + ",".join(SUITES) + " (backend "
                         "= the per-target lambda-vs-bounding A/B rows "
                         "alone; they are also part of map/attn)")
    ap.add_argument("--json", default=None,
                    help="artifact path (default: BENCH_<tag>.json at "
                         "the repo root)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the JSON artifact")
    ap.add_argument("--tag", default=None,
                    help="artifact tag (default: jax backend)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    if only is not None:
        unknown = sorted(only - set(SUITES))
        if unknown:
            ap.error(f"unknown suite(s) {', '.join(unknown)}; "
                     f"available: {', '.join(SUITES)}")

    import jax

    from . import (bench_attention_domains, bench_ca, bench_map_time,
                   bench_serve, bench_sierpinski_map,
                   bench_space_efficiency, common)

    print("name,us_per_call,derived")
    if only is None or "map" in only:
        bench_sierpinski_map.run()
    if only is None or "space" in only:
        bench_space_efficiency.run()
    if only is None or "time" in only:
        bench_map_time.run()
    if only is None or "sched" in only:
        bench_ca.run_sched_ab()
    if only is None or "shard" in only:
        bench_ca.run_shard_ab()
    if only is None or "overlap" in only:
        bench_ca.run_overlap_ab()
    if only is None or "ca" in only:
        bench_ca.run(sched_ab=False)
    if only is None or "attn" in only:
        bench_attention_domains.run()
    if only is None or "serve" in only:
        bench_serve.run()
        bench_serve.run_page_sizes()
        bench_serve.run_zigzag_balance()
    if only is not None and "backend" in only:
        bench_sierpinski_map.run_backend_ab()
        bench_attention_domains.run_backend_ab()
    if not args.no_json:
        path = args.json
        if path is None:
            tag = args.tag or jax.default_backend()
            root = os.path.dirname(os.path.dirname(
                os.path.abspath(__file__)))
            path = os.path.join(root, f"BENCH_{tag}.json")
        common.dump_json(path)


if __name__ == '__main__':
    main()
