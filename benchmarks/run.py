# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# optionally mirror the rows to a JSON artifact with --json.
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: map,space,time,ca,attn")
    ap.add_argument("--json", default=None,
                    help="also write all rows to this JSON file")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_attention_domains, bench_ca, bench_map_time,
                   bench_sierpinski_map, bench_space_efficiency, common)

    print("name,us_per_call,derived")
    if only is None or "map" in only:
        bench_sierpinski_map.run()
    if only is None or "space" in only:
        bench_space_efficiency.run()
    if only is None or "time" in only:
        bench_map_time.run()
    if only is None or "ca" in only:
        bench_ca.run()
    if only is None or "attn" in only:
        bench_attention_domains.run()
    if args.json:
        common.dump_json(args.json)


if __name__ == '__main__':
    main()
